"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch.

Dispatch is gather/scatter based (argsort-free GShard-style positions via
cumsum ranking), NOT the one-hot einsum formulation — the einsum dispatch is
O(T^2) FLOPs per group and would dominate the roofline. With the expert dim
sharded over the mesh ``model`` axis, GSPMD lowers the scatter/gather pair
to all-to-all collectives (expert parallelism).

``moe_forward_dense`` is the pure/naive oracle used by tests.
"""
from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_mlp, dense_init, mlp_init, param_dtype_of

Params = Any

# Dispatch implementation: "scatter" (capacity-based, lowers to all-to-all
# when experts are sharded over the tp axis) or "dense" (masked batched
# einsum over ALL experts — compute overhead E/top_k, but no scatter; the
# right choice when E doesn't divide the tp axis, where GSPMD would
# replicate the (E*C, D) dispatch buffer on every device).
_MOE_IMPL: ContextVar[str] = ContextVar("moe_impl", default="scatter")

# Optional sharding constraint for the dispatch buffer's feature dim.
# Without it GSPMD materializes the (E*C, d) buffer replicated and
# all-reduces it per MoE layer (measured 1.8 TB/step wire on llama4
# prefill); with d sharded over tp, the expert-sharded weights pull the
# buffer through an all-to-all instead (the intended EP dataflow).
_MOE_BUF_SPEC: ContextVar = ContextVar("moe_buf_spec", default=None)


@contextlib.contextmanager
def moe_impl(name: str, buf_spec=None):
    tok = _MOE_IMPL.set(name)
    tok2 = _MOE_BUF_SPEC.set(buf_spec)
    try:
        yield
    finally:
        _MOE_IMPL.reset(tok)
        _MOE_BUF_SPEC.reset(tok2)


def _buf_hint(x):
    spec = _MOE_BUF_SPEC.get()
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_init(key, c: ModelConfig) -> Params:
    pd = param_dtype_of(c)
    eff = c.expert_d_ff or c.d_ff
    ks = jax.random.split(key, c.n_experts + 2)
    experts = [mlp_init(ks[i], c, eff) for i in range(c.n_experts)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *experts)
    p = {
        "router": dense_init(ks[-1], c.d_model, c.n_experts, jnp.float32),
        "experts": stacked,
    }
    if c.moe_shared:
        p["shared"] = mlp_init(ks[-2], c, eff)
    return p


def router_topk(c: ModelConfig, p: Params, x2d: jax.Array):
    """x2d: (T, D) -> (weights (T,k), experts (T,k), aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    weights, experts = jax.lax.top_k(probs, c.top_k)            # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    T = x2d.shape[0]
    me = probs.mean(axis=0)                                     # (E,)
    one_hot = jax.nn.one_hot(experts[:, 0], c.n_experts, dtype=jnp.float32)
    ce = one_hot.mean(axis=0)
    aux = c.n_experts * jnp.sum(me * ce)
    return weights, experts, aux


def expert_capacity(c: ModelConfig, n_tokens: int) -> int:
    cap = int(math.ceil(n_tokens * c.top_k * c.capacity_factor / c.n_experts))
    return max(cap, 4)


def _apply_experts(c: ModelConfig, experts: Params, buf: jax.Array) -> jax.Array:
    """buf: (E, C, D) -> (E, C, D) via per-expert MLP (batched einsum)."""
    if c.act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, experts["wi_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, experts["wi_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, experts["wi"])
        if "bi" in experts:
            h = h + experts["bi"][:, None]
        h = jax.nn.gelu(h)
    out = jnp.einsum("ecf,efd->ecd", h, experts["wo"])
    if "bo" in experts:
        out = out + experts["bo"][:, None]
    return out


def moe_forward(c: ModelConfig, p: Params, x: jax.Array):
    """x: (B, S, D) -> (y (B,S,D), aux_loss)."""
    if _MOE_IMPL.get() == "dense":
        return moe_forward_einsum(c, p, x)
    b, s, d = x.shape
    T = b * s
    x2d = x.reshape(T, d)
    weights, experts_idx, aux = router_topk(c, p, x2d)
    C = expert_capacity(c, T)
    E = c.n_experts

    # position of each (token, choice) within its expert, via cumsum ranking
    sel = jax.nn.one_hot(experts_idx, E, dtype=jnp.int32)       # (T, k, E)
    sel_flat = sel.reshape(T * c.top_k, E)
    pos = jnp.cumsum(sel_flat, axis=0) * sel_flat - 1           # (T*k, E)
    pos_in_expert = pos.max(axis=-1)                            # (T*k,)
    keep = (pos_in_expert >= 0) & (pos_in_expert < C)
    dest = experts_idx.reshape(-1) * C + jnp.clip(pos_in_expert, 0, C - 1)
    dest = jnp.where(keep, dest, E * C)                         # overflow slot

    xk = jnp.repeat(x2d, c.top_k, axis=0)                       # (T*k, D)
    buf = _buf_hint(jnp.zeros((E * C + 1, d), x.dtype).at[dest].add(xk))
    buf = buf[:-1].reshape(E, C, d)

    out_buf = _apply_experts(c, p["experts"], buf).reshape(E * C, d)
    out_buf = _buf_hint(
        jnp.concatenate([out_buf, jnp.zeros((1, d), x.dtype)]))

    gathered = out_buf[dest]                                    # (T*k, D)
    wk = (weights.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    y = (gathered * wk).reshape(T, c.top_k, d).sum(axis=1)

    if c.moe_shared:
        y = y + apply_mlp(c, p["shared"], x2d)
    return y.reshape(b, s, d), aux


def moe_forward_einsum(c: ModelConfig, p: Params, x: jax.Array):
    """Masked batched-einsum MoE (all experts on all tokens; no dropping).

    Shards cleanly with the expert FFN dim over tp: (T, E, F) activations
    stay local, the combine einsum contracts (E, F) -> one small AR. Used
    for archs whose expert count doesn't divide the tp axis (DESIGN.md
    par.5: granite-moe's 40 experts vs the 16-way model axis).
    """
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    weights, experts_idx, aux = router_topk(c, p, x2d)
    # dense (T, E) weight matrix with zeros for unrouted experts
    wfull = jnp.zeros((b * s, c.n_experts), jnp.float32)
    wfull = wfull.at[jnp.arange(b * s)[:, None], experts_idx].set(weights)
    ex = p["experts"]
    if c.act == "swiglu":
        g = jnp.einsum("td,edf->tef", x2d, ex["wi_gate"])
        u = jnp.einsum("td,edf->tef", x2d, ex["wi_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("td,edf->tef", x2d, ex["wi"])
        if "bi" in ex:
            h = h + ex["bi"][None]
        h = jax.nn.gelu(h)
    y = jnp.einsum("tef,te,efd->td", h, wfull.astype(h.dtype), ex["wo"])
    if "bo" in ex:
        y = y + jnp.einsum("te,ed->td", wfull.astype(h.dtype), ex["bo"])
    if c.moe_shared:
        y = y + apply_mlp(c, p["shared"], x2d)
    return y.reshape(b, s, d), aux


def moe_forward_dense(c: ModelConfig, p: Params, x: jax.Array):
    """Oracle: loop over experts with dense masks (no capacity drops)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    weights, experts_idx, aux = router_topk(c, p, x2d)
    y = jnp.zeros_like(x2d)
    for e in range(c.n_experts):
        pe = jax.tree.map(lambda w: w[e], p["experts"])
        ye = apply_mlp(c, pe, x2d)
        w_e = jnp.where(experts_idx == e, weights, 0.0).sum(-1)  # (T,)
        y = y + ye * w_e[:, None].astype(x.dtype)
    if c.moe_shared:
        y = y + apply_mlp(c, p["shared"], x2d)
    return y.reshape(b, s, d), aux
