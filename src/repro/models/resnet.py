"""ResNet50 (v1.5) in JAX — the paper's computer-vision benchmark case.

Data-parallel training with an all-reduce over the mesh ``data`` axis is the
Horovod analog used by the tf_cnn_benchmarks fork in CARAML. BatchNorm uses
per-step batch statistics (training mode) with running stats carried in a
separate state pytree, matching the benchmark's from-scratch training mode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.resnet50 import ResNetConfig

Params = Any


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    std = jnp.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std).astype(dtype)


def _bn_init(ch, dtype):
    return {"scale": jnp.ones((ch,), dtype), "bias": jnp.zeros((ch,), dtype)}


def _bn_state(ch):
    return {"mean": jnp.zeros((ch,), jnp.float32),
            "var": jnp.ones((ch,), jnp.float32)}


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=(0, 1, 2))
    var = jnp.var(xf, axis=(0, 1, 2))
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), (mean, var)


def bottleneck_init(key, cin, width, stride, dtype):
    ks = jax.random.split(key, 4)
    cout = width * 4
    p = {
        "conv1": _conv_init(ks[0], 1, 1, cin, width, dtype), "bn1": _bn_init(width, dtype),
        "conv2": _conv_init(ks[1], 3, 3, width, width, dtype), "bn2": _bn_init(width, dtype),
        "conv3": _conv_init(ks[2], 1, 1, width, cout, dtype), "bn3": _bn_init(cout, dtype),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], 1, 1, cin, cout, dtype)
        p["bn_proj"] = _bn_init(cout, dtype)
    return p


def bottleneck(p, x, stride):
    h, _ = batchnorm(p["bn1"], conv(x, p["conv1"]))
    h = jax.nn.relu(h)
    h, _ = batchnorm(p["bn2"], conv(h, p["conv2"], stride))
    h = jax.nn.relu(h)
    h, _ = batchnorm(p["bn3"], conv(h, p["conv3"]))
    sc = x
    if "proj" in p:
        sc, _ = batchnorm(p["bn_proj"], conv(x, p["proj"], stride))
    return jax.nn.relu(h + sc)


def init(key, c: ResNetConfig) -> Params:
    dtype = jnp.dtype(c.param_dtype)
    keys = jax.random.split(key, 3 + sum(c.stage_sizes))
    ki = iter(keys)
    p = {"stem": _conv_init(next(ki), 7, 7, 3, c.width, dtype),
         "bn_stem": _bn_init(c.width, dtype), "stages": []}
    cin = c.width
    for s, n_blocks in enumerate(c.stage_sizes):
        width = c.width * (2 ** s)
        stage = []
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            stage.append(bottleneck_init(next(ki), cin, width, stride, dtype))
            cin = width * 4
        p["stages"].append(stage)
    p["head"] = (jax.random.normal(next(ki), (cin, c.n_classes), jnp.float32)
                 * 0.01).astype(dtype)
    p["head_b"] = jnp.zeros((c.n_classes,), dtype)
    return p


def forward(c: ResNetConfig, p: Params, images: jax.Array) -> jax.Array:
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    x = images.astype(jnp.dtype(c.dtype))
    x = conv(x, p["stem"], stride=2)
    x, _ = batchnorm(p["bn_stem"], x)
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for s, stage in enumerate(p["stages"]):
        for b, block in enumerate(stage):
            stride = 2 if (b == 0 and s > 0) else 1
            x = bottleneck(block, x, stride)
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head"] + p["head_b"]
