"""jit'd dispatch wrappers for the Pallas kernels.

``impl="pallas"`` targets TPU (or interpret mode on CPU for validation);
``impl="xla"`` routes to the pure-jnp reference path. The model code uses
the XLA path for the CPU dry-run; real-TPU deployments flip the flag.

Selection map (who runs what, where):

  flash_attention        prefill/train attention; ``impl="pallas"`` on
                         TPU, ``impl="xla"`` (ref) on CPU.
  paged_decode_attention the serve decode hot path over a paged KV pool
                         (``serve.cache.PagedKVCache`` block tables).
                         ``impl="xla"`` gathers the table into a dense
                         view (the CPU/dry-run path the benchmark
                         measures); ``impl="pallas"`` walks the table
                         with scalar-prefetch DMA — the TPU deployment
                         path, validated on CPU via ``interpret=True``.
  paged_prefill_attention the serve *prefill* hot path: a chunk of Q
                         positions vs [the slot's paged prefix blocks ++
                         the chunk's own suffix KV]. Same xla/pallas
                         split; the xla path is bit-compatible with the
                         engine's dense phased prefill (the serve stream
                         contract).
  rmsnorm                elementwise; same pallas/xla split.

Both paged ops accept optional ``k_scale``/``v_scale`` (n_blocks, Kh)
f32 marking an int8-quantized pool; dequant happens inside the kernel's
KV load (pallas) or right after the gather (xla ref).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import (
    paged_decode_attention as _paged_decode_pallas,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.prefill_attention import (
    paged_prefill_attention as _paged_prefill_pallas,
)
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_pallas


@partial(jax.jit, static_argnames=("causal", "window", "impl", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "pallas",
                    interpret: bool = False, block_q: int = 128,
                    block_k: int = 128):
    """q: (B, Sq, H, Dh); k/v: (B, Skv, Kh, Dh) — model layout (seq-major).

    Transposed internally to the kernel's (B, H, S, Dh) layout.
    """
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_pallas(qt, kt, vt, causal=causal, window=window,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("window", "impl", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, tables, lengths, *,
                           window: Optional[int] = None, impl: str = "pallas",
                           interpret: bool = False, k_scale=None,
                           v_scale=None):
    """Single-token GQA decode over a paged KV pool.

    q: (B, H, Dh); k/v_pool: (n_blocks, bs, Kh, Dh); tables: (B, nb)
    int32 physical block ids (position order, trash block 0 for unowned
    columns); lengths: (B,) int32 KV length incl. the current token.
    k/v_scale: optional (n_blocks, Kh) f32 int8-pool scales.
    """
    if impl == "xla":
        return ref.paged_decode_attention_ref(q, k_pool, v_pool, tables,
                                              lengths, window=window,
                                              k_scale=k_scale,
                                              v_scale=v_scale)
    return _paged_decode_pallas(q, k_pool, v_pool, tables, lengths,
                                window=window, k_scale=k_scale,
                                v_scale=v_scale, interpret=interpret)


@partial(jax.jit, static_argnames=("window", "impl", "interpret",
                                   "block_q", "block_k"))
def paged_prefill_attention(q, k_suffix, v_suffix, k_pool, v_pool, tables, *,
                            window: Optional[int] = None,
                            impl: str = "pallas", interpret: bool = False,
                            block_q: int = 128, block_k: int = 128,
                            k_scale=None, v_scale=None):
    """Chunk-of-queries causal GQA attention over [paged prefix ++ own
    suffix KV].

    q: (B, Sq, H, Dh); k/v_suffix: (B, Sq, Kh, Dh); k/v_pool:
    (n_blocks, bs, Kh, Dh); tables: (B, npre) int32 prefix block ids in
    position order (queries sit at global positions npre*bs + i).
    k/v_scale: optional (n_blocks, Kh) f32 int8-pool scales.
    """
    if impl == "xla":
        return ref.paged_prefill_attention_ref(q, k_suffix, v_suffix,
                                               k_pool, v_pool, tables,
                                               window=window, k_scale=k_scale,
                                               v_scale=v_scale)
    return _paged_prefill_pallas(q, k_suffix, v_suffix, k_pool, v_pool,
                                 tables, window=window, k_scale=k_scale,
                                 v_scale=v_scale, block_q=block_q,
                                 block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("impl", "interpret", "eps"))
def rmsnorm(x, scale, *, eps: float = 1e-5, impl: str = "pallas",
            interpret: bool = False):
    if impl == "xla":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    return _rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
