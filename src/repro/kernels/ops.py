"""jit'd dispatch wrappers for the Pallas kernels.

``impl="pallas"`` targets TPU (or interpret mode on CPU for validation);
``impl="xla"`` routes to the pure-jnp reference path. The model code uses
the XLA path for the CPU dry-run; real-TPU deployments flip the flag.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_pallas


@partial(jax.jit, static_argnames=("causal", "window", "impl", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "pallas",
                    interpret: bool = False, block_q: int = 128,
                    block_k: int = 128):
    """q: (B, Sq, H, Dh); k/v: (B, Skv, Kh, Dh) — model layout (seq-major).

    Transposed internally to the kernel's (B, H, S, Dh) layout.
    """
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_pallas(qt, kt, vt, causal=causal, window=window,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("impl", "interpret", "eps"))
def rmsnorm(x, scale, *, eps: float = 1e-5, impl: str = "pallas",
            interpret: bool = False):
    if impl == "xla":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    return _rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
