"""jit'd dispatch wrappers for the Pallas kernels.

``impl="pallas"`` targets TPU (or interpret mode on CPU for validation);
``impl="xla"`` routes to the pure-jnp reference path. The model code uses
the XLA path for the CPU dry-run; real-TPU deployments flip the flag.

Selection map (who runs what, where):

  flash_attention        prefill/train attention; ``impl="pallas"`` on
                         TPU, ``impl="xla"`` (ref) on CPU.
  paged_decode_attention the serve decode hot path over a paged KV pool
                         (``serve.cache.PagedKVCache`` block tables).
                         ``impl="xla"`` gathers the table into a dense
                         view (the CPU/dry-run path the benchmark
                         measures); ``impl="pallas"`` walks the table
                         with scalar-prefetch DMA — the TPU deployment
                         path, validated on CPU via ``interpret=True``.
  rmsnorm                elementwise; same pallas/xla split.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import (
    paged_decode_attention as _paged_decode_pallas,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_pallas


@partial(jax.jit, static_argnames=("causal", "window", "impl", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "pallas",
                    interpret: bool = False, block_q: int = 128,
                    block_k: int = 128):
    """q: (B, Sq, H, Dh); k/v: (B, Skv, Kh, Dh) — model layout (seq-major).

    Transposed internally to the kernel's (B, H, S, Dh) layout.
    """
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_pallas(qt, kt, vt, causal=causal, window=window,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("window", "impl", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, tables, lengths, *,
                           window: Optional[int] = None, impl: str = "pallas",
                           interpret: bool = False):
    """Single-token GQA decode over a paged KV pool.

    q: (B, H, Dh); k/v_pool: (n_blocks, bs, Kh, Dh); tables: (B, nb)
    int32 physical block ids (position order, trash block 0 for unowned
    columns); lengths: (B,) int32 KV length incl. the current token.
    """
    if impl == "xla":
        return ref.paged_decode_attention_ref(q, k_pool, v_pool, tables,
                                              lengths, window=window)
    return _paged_decode_pallas(q, k_pool, v_pool, tables, lengths,
                                window=window, interpret=interpret)


@partial(jax.jit, static_argnames=("impl", "interpret", "eps"))
def rmsnorm(x, scale, *, eps: float = 1e-5, impl: str = "pallas",
            interpret: bool = False):
    if impl == "xla":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    return _rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
