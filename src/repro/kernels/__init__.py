from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas

__all__ = ["ops", "ref", "flash_attention_pallas", "rmsnorm_pallas"]
