"""Pallas TPU kernels + jnp oracles for the hot paths.

Layout of the package:

  ref.py               pure-jnp oracles — the semantics every kernel
                       must match (swept + property-tested).
  flash_attention.py   prefill/train flash attention (GQA, causal,
                       windowed) over dense (B, S) layouts.
  decode_attention.py  single-token GQA decode over the *paged* KV
                       layout: fixed-size blocks in a shared pool,
                       per-sequence block tables (scalar-prefetch index
                       maps), masking by true per-sequence length —
                       the serve decode hot path.
  rmsnorm.py           fused rmsnorm.
  ops.py               jit'd dispatch: ``impl="pallas"`` on TPU (or
                       ``interpret=True`` on CPU for validation),
                       ``impl="xla"`` for the reference/dry-run path —
                       the selection map lives in its docstring.
"""
from repro.kernels import ops, ref
from repro.kernels.decode_attention import (
    paged_decode_attention as paged_decode_attention_pallas,
)
from repro.kernels.flash_attention import flash_attention as flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas

__all__ = ["ops", "ref", "flash_attention_pallas",
           "paged_decode_attention_pallas", "rmsnorm_pallas"]
