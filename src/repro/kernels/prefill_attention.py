"""Pallas TPU paged prefill flash attention (chunk of queries vs a block
table + the chunk's own suffix KV).

The serve prefill hot path that decode's paged kernel left behind:
chunked prefill (every chunk after the first) and prefix-cached suffix
prefill both attend a ``Sq``-token query slice against [the slot's first
``npre`` pool blocks ++ the slice's own fresh KV]. Until this kernel,
that ran as a dense XLA gather (``k_pool[tables]`` materialized per
layer) followed by masked SDPA; here the prefix KV never leaves the
pool.

TPU-native design (the ``decode_attention.py`` block-table walk fused
with the ``flash_attention.py`` online-softmax Q loop):
  - grid ``(B, Kh, nQ, npre + nS)``; the KV dimension is innermost,
    which Pallas TPU executes SEQUENTIALLY per core, so the
    online-softmax running state (m, l, acc) lives in VMEM scratch and
    is carried across a query tile's prefix blocks and suffix tiles;
  - the block table rides in as **scalar prefetch**
    (``pltpu.PrefetchScalarGridSpec``): for KV step ``j < npre`` the
    k/v BlockSpec index map reads ``tables[b, j]`` and DMAs the
    *physical* pool block — the paged indirection costs one SMEM
    lookup, not a gather; steps ``j >= npre`` stream the suffix KV
    tiles ``(j - npre)`` from the freshly projected k/v instead;
  - GQA is expressed in the q layout: q is viewed as
    ``(B, Kh, G, Sq, Dh)`` so the ``G = H // Kh`` query heads sharing
    a KV head are one MXU operand; repeated KV is never materialized;
  - causal masking is positional with the chunk's global offset
    ``pos_offset = npre * bs`` folded in: prefix blocks sit entirely
    below every query position (prefixes are whole blocks of real
    tokens), so only the sliding window can exclude them; suffix tiles
    beyond the causal diagonal — and blocks/tiles outside the window —
    are skipped with ``pl.when`` (no MXU work);
  - int8 KV pools dequantize inside the load: per-block-per-head
    symmetric scales ``(n_blocks, Kh)`` ride in as (1, 1) blocks
    addressed by the same table lookup, and ``k * scale`` happens on
    the VMEM tile — fp prefix KV is never materialized anywhere.

Validated against ``kernels.ref.paged_prefill_attention_ref`` in
interpret mode (tests sweep shapes, block sizes, GQA groups, prefix
depths / pos_offset, shuffled tables, windows, dtypes, int8 scales).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(tables_ref, *refs, scale: float, bs: int, bq: int,
                    bk: int, npre: int, n_kv: int, pos_offset: int,
                    window: Optional[int], quantized: bool):
    if quantized:
        (q_ref, kp_ref, vp_ref, ksc_ref, vsc_ref, ks_ref, vs_ref, o_ref,
         m_scr, l_scr, acc_scr) = refs
    else:
        (q_ref, kp_ref, vp_ref, ks_ref, vs_ref, o_ref,
         m_scr, l_scr, acc_scr) = refs
    iq = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_q = pos_offset + iq * bq
    last_q = first_q + bq - 1

    def accum(k, v, kpos0):
        """Online-softmax update with one KV tile (k/v: (tile, Dh) f32,
        covering global positions [kpos0, kpos0 + tile))."""
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, bq, Dh)
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())))  # (G, bq, t)
        qpos = first_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        mask = kpos <= qpos
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=2)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())))
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new

    # prefix pool blocks: whole blocks of real tokens strictly below
    # pos_offset <= first_q, so causality never excludes them — only
    # the sliding window can.
    run_pre = j < npre
    if window is not None:
        run_pre = jnp.logical_and(run_pre, (j + 1) * bs - 1 > first_q - window)

    @pl.when(run_pre)
    def _pool_block():
        k = kp_ref[0, :, 0].astype(jnp.float32)                # (bs, Dh)
        v = vp_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ksc_ref[0, 0]
            v = v * vsc_ref[0, 0]
        accum(k, v, j * bs)

    # suffix tiles: global start pos_offset + (j - npre) * bk; tiles
    # past the causal diagonal of this q tile are skipped.
    first_k = pos_offset + (j - npre) * bk
    run_suf = jnp.logical_and(j >= npre, first_k <= last_q)
    if window is not None:
        run_suf = jnp.logical_and(run_suf, first_k + bk - 1 > first_q - window)

    @pl.when(run_suf)
    def _suffix_tile():
        accum(ks_ref[0, :, 0].astype(jnp.float32),
              vs_ref[0, :, 0].astype(jnp.float32), first_k)

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


def paged_prefill_attention(q: jax.Array, k_suffix: jax.Array,
                            v_suffix: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, tables: jax.Array, *,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None,
                            block_q: int = 128, block_k: int = 128,
                            interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, Dh); k/v_suffix: (B, Sq, Kh, Dh) — the chunk's own
    freshly projected KV; k/v_pool: (n_blocks, bs, Kh, Dh) — the shared
    paged pool (int8 when k/v_scale (n_blocks, Kh) f32 are given);
    tables: (B, npre) int32 physical ids of each row's prefix blocks in
    position order. Queries sit at global positions
    ``pos_offset + i`` with ``pos_offset = npre * bs`` (prefixes are
    whole blocks). Returns (B, Sq, H, Dh) in q.dtype.
    """
    b, sq, h, dh = q.shape
    bs, kh = k_pool.shape[1], k_pool.shape[2]
    assert h % kh == 0, (h, kh)
    assert k_suffix.shape == (b, sq, kh, dh), (k_suffix.shape, (b, sq, kh, dh))
    assert (k_scale is None) == (v_scale is None)
    g = h // kh
    npre = tables.shape[1]
    assert npre >= 1, "paged prefill needs >= 1 prefix block (cold " \
        "prefill with no prefix takes the dense path)"
    pos_offset = npre * bs
    # tiles must divide Sq exactly; walk down from the requested size
    # (engine buckets are block_size multiples, so this lands on a large
    # divisor — e.g. Sq=144 with block_q=128 tiles at 72)
    bq = min(block_q, sq)
    while sq % bq:
        bq -= 1
    bk = min(block_k, sq)
    while sq % bk:
        bk -= 1
    n_q, n_suf = sq // bq, sq // bk
    n_kv = npre + n_suf
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    quantized = k_scale is not None

    kernel = functools.partial(
        _prefill_kernel, scale=scale, bs=bs, bq=bq, bk=bk, npre=npre,
        n_kv=n_kv, pos_offset=pos_offset, window=window, quantized=quantized)

    def pool_index(bi, khi, iq, j, tables_ref):
        # j >= npre clamps to the last prefix entry: a valid (never
        # computed-on) block, so the dead DMA cannot fault.
        return (tables_ref[bi, jnp.minimum(j, npre - 1)], 0, khi, 0)

    def scale_index(bi, khi, iq, j, tables_ref):
        return (tables_ref[bi, jnp.minimum(j, npre - 1)], khi)

    def suffix_index(bi, khi, iq, j, tables_ref):
        return (bi, jnp.maximum(j - npre, 0), khi, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, bq, dh),
                     lambda bi, khi, iq, j, tr: (bi, khi, 0, iq, 0)),
        pl.BlockSpec((1, bs, 1, dh), pool_index),
        pl.BlockSpec((1, bs, 1, dh), pool_index),
    ]
    operands = [q.transpose(0, 2, 1, 3).reshape(b, kh, g, sq, dh),
                k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1), scale_index),
                     pl.BlockSpec((1, 1), scale_index)]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    in_specs += [
        pl.BlockSpec((1, bk, 1, dh), suffix_index),
        pl.BlockSpec((1, bk, 1, dh), suffix_index),
    ]
    operands += [k_suffix, v_suffix]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, n_q, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, bq, dh),
                               lambda bi, khi, iq, j, tr: (bi, khi, 0, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, sq, dh), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), *operands)
    return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
