"""Pallas TPU flash attention (GQA, causal, windowed).

TPU-native design (not a CUDA port — see DESIGN.md):
  - grid (B*H, nQ, nKV); the KV dimension is innermost, which Pallas TPU
    executes SEQUENTIALLY per core, so the online-softmax running state
    (m, l, acc) lives in VMEM scratch and is carried across KV steps;
  - BlockSpecs tile q/k/v/o into MXU-aligned (block, d_head) VMEM blocks
    (d_head 64/128 matches the 128-lane MXU systolic array);
  - GQA is expressed in the k/v index_map (query head h reads KV head
    h // group), so repeated KV is never materialized;
  - causal/windowed masking is positional per block; fully-masked KV
    blocks are skipped with pl.when (no MXU work), making windowed
    attention honestly sub-quadratic.

Validated against kernels/ref.py in interpret mode (tests sweep shapes,
dtypes, GQA groups, window sizes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_kv: int, sq: int, skv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # queries are the LAST sq positions of the kv stream (sq == skv for
    # self-attention; sq < skv when decoding a suffix against a prefix).
    q_off = skv - sq
    run = True
    if causal:
        first_q = iq * bq + q_off
        last_q = first_q + bq - 1
        first_k = ik * bk
        run = first_k <= last_q  # KV block intersects the visible triangle
        if window is not None:
            run = jnp.logical_and(run, (ik + 1) * bk - 1 > first_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                      # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = (iq * bq + q_off
                    + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos <= qpos
            if window is not None:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                      # (bk, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, Dh); k/v: (B, Kh, Skv, Dh). Returns (B, H, Sq, Dh)."""
    b, h, sq, dh = q.shape
    kh, skv = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    g = h // kh
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_q, n_kv = sq // bq, skv // bk
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv, sq=sq, skv=skv)

    def kv_index(bh, iq, ik):
        return ((bh // h) * kh + (bh % h) // g, 0, ik, 0)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bh, iq, ik: (bh, 0, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh), kv_index),
            pl.BlockSpec((1, 1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda bh, iq, ik: (bh, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * h, 1, sq, dh), k.reshape(b * kh, 1, skv, dh),
      v.reshape(b * kh, 1, skv, dh))
    return out.reshape(b, h, sq, dh)
