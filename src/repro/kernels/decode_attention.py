"""Pallas TPU paged decode attention (single-token GQA over a block pool).

The serve decode hot path: each sequence holds one new query token and a
*paged* KV history — fixed-size blocks scattered through a shared pool,
addressed by a per-sequence block table (``serve.cache.PagedKVCache``).
The kernel walks only the table, never a dense ``(B, max_len)`` cache
row, so attention work scales with the tokens a sequence actually owns
instead of the padded slot capacity.

TPU-native design (mirrors ``flash_attention.py``):
  - grid ``(B, Kh, nb)``; the block dimension is innermost, which Pallas
    TPU executes SEQUENTIALLY per core, so the online-softmax running
    state (m, l, acc) lives in VMEM scratch and is carried across the
    sequence's blocks;
  - the block table and true lengths ride in as **scalar prefetch**
    arguments (``pltpu.PrefetchScalarGridSpec``): the k/v BlockSpec
    index map reads ``tables[b, j]`` to DMA the *physical* pool block —
    the paged indirection costs one SMEM lookup, not a gather;
  - GQA is expressed in the q layout: q is viewed as ``(B, Kh, G, Dh)``
    so the ``G = H // Kh`` query heads sharing a KV head are one MXU
    operand; repeated KV is never materialized;
  - blocks at or beyond a sequence's length are skipped with ``pl.when``
    (no MXU work); unowned table columns point at the trash block 0, so
    the skipped DMA cannot fault. Masking inside the boundary block is
    positional (``kpos < length``), with the optional sliding window
    applied the same way as the slotted path;
  - int8 KV pools dequantize inside the load: per-block-per-head
    symmetric scales ``(n_blocks, Kh)`` ride in as (1, 1) blocks
    addressed by the same table lookup, and ``k * scale`` happens on the
    VMEM tile — fp KV is never materialized anywhere.

Validated against ``kernels.ref.paged_decode_attention_ref`` in
interpret mode (tests sweep block sizes, GQA groups, ragged lengths and
alloc/free block-table permutations).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(tables_ref, lengths_ref, *refs, scale: float, bs: int,
                   nb: int, window: Optional[int], quantized: bool):
    if quantized:
        (q_ref, k_ref, v_ref, ksc_ref, vsc_ref, o_ref,
         m_scr, l_scr, acc_scr) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    run = j * bs < length                      # block holds visible keys
    if window is not None:
        run = jnp.logical_and(run, (j + 1) * bs - 1 > length - 1 - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, Dh)
        if quantized:
            k = k * ksc_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bs)
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None:
            mask = jnp.logical_and(mask, kpos > length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, Dh)
        if quantized:
            v = v * vsc_ref[0, 0]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           tables: jax.Array, lengths: jax.Array, *,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, Dh); k/v_pool: (n_blocks, bs, Kh, Dh); tables: (B, nb)
    int32 physical block ids; lengths: (B,) int32 KV length per sequence
    including the current token. ``k_scale``/``v_scale`` (n_blocks, Kh)
    f32 mark an int8 pool — blocks dequantize on their VMEM tile, fp KV
    is never materialized. Returns (B, H, Dh)."""
    b, h, dh = q.shape
    bs, kh = k_pool.shape[1], k_pool.shape[2]
    assert h % kh == 0, (h, kh)
    assert (k_scale is None) == (v_scale is None)
    g = h // kh
    nb = tables.shape[1]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    quantized = k_scale is not None

    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs, nb=nb,
                               window=window, quantized=quantized)

    def kv_index(bi, khi, j, tables_ref, lengths_ref):
        return (tables_ref[bi, j], 0, khi, 0)

    def scale_index(bi, khi, j, tables_ref, lengths_ref):
        return (tables_ref[bi, j], khi)

    in_specs = [
        pl.BlockSpec((1, 1, g, dh),
                     lambda bi, khi, j, tr, lr: (bi, khi, 0, 0)),
        pl.BlockSpec((1, bs, 1, dh), kv_index),
        pl.BlockSpec((1, bs, 1, dh), kv_index),
    ]
    operands = [q.reshape(b, kh, g, dh), k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1), scale_index),
                     pl.BlockSpec((1, 1), scale_index)]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bi, khi, j, tr, lr: (bi, khi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    return out.reshape(b, h, dh)
