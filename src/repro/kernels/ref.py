"""Pure-jnp oracles for the Pallas kernels. These define the semantics the
kernels must match (asserted over shape/dtype sweeps in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, Dh); k/v: (B, Skv, Kh, Dh), H % Kh == 0 (GQA).

    fp32 softmax, bf16-friendly. Returns (B, Sq, H, Dh) in q.dtype.
    """
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, sq, kh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf)
    if causal:
        qpos = jnp.arange(sq)[:, None] + (k.shape[1] - sq)
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, vf)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def dequantize_pool(pool: jax.Array, tables: jax.Array,
                    pool_scale: Optional[jax.Array]) -> jax.Array:
    """Gather ``pool[tables]`` -> (B, nb, bs, Kh, Dh) f32, applying the
    per-block-per-head symmetric scales ``(n_blocks, Kh)`` when the pool
    is int8-quantized (``pool_scale`` given)."""
    g = pool[tables].astype(jnp.float32)
    if pool_scale is not None:
        g = g * pool_scale[tables][:, :, None, :, None].astype(jnp.float32)
    return g


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, tables: jax.Array,
                               lengths: jax.Array, *,
                               window: Optional[int] = None,
                               scale: Optional[float] = None,
                               k_scale: Optional[jax.Array] = None,
                               v_scale: Optional[jax.Array] = None
                               ) -> jax.Array:
    """Single-token GQA decode attention over a paged KV pool.

    q:      (B, H, Dh)        — one query token per sequence;
    k_pool: (n_blocks, bs, Kh, Dh) — the shared KV block pool (v_pool
            alike); block contents cover contiguous position ranges
            [j*bs, (j+1)*bs) of whichever sequence owns the block;
    tables: (B, nb) int32     — per-sequence physical block ids, in
            position order (column j holds positions [j*bs, (j+1)*bs));
            columns a sequence does not own point at the trash block 0;
    lengths:(B,) int32        — true KV length per sequence *including*
            the current token (the query sits at position lengths-1).

    Visible keys are kpos < length (causal: everything at or before the
    query), additionally kpos > length-1-window when windowed. fp32
    softmax; returns (B, H, Dh) in q.dtype. This is the semantics oracle
    the Pallas kernel (kernels/decode_attention.py) must match.

    ``k_scale``/``v_scale`` (n_blocks, Kh) f32 mark an int8-quantized
    pool: blocks dequantize (symmetric, per block per KV head) right
    after the gather — the XLA stand-in for the kernel's in-VMEM
    dequant.
    """
    b, h, dh = q.shape
    nb = tables.shape[1]
    bs, kh = k_pool.shape[1], k_pool.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    k = dequantize_pool(k_pool, tables, k_scale).reshape(b, nb * bs, kh, dh)
    v = dequantize_pool(v_pool, tables, v_scale).reshape(b, nb * bs, kh, dh)
    qf = q.astype(jnp.float32).reshape(b, kh, g, dh) * scale
    scores = jnp.einsum("bkgd,btkd->bkgt", qf, k)
    kpos = jnp.arange(nb * bs)[None, :]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos > lengths[:, None] - 1 - window
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v)
    return out.reshape(b, h, dh).astype(q.dtype)


def paged_prefill_attention_ref(q: jax.Array, k_suffix: jax.Array,
                                v_suffix: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, tables: jax.Array, *,
                                window: Optional[int] = None,
                                scale: Optional[float] = None,
                                k_scale: Optional[jax.Array] = None,
                                v_scale: Optional[jax.Array] = None
                                ) -> jax.Array:
    """Chunk-of-queries causal GQA attention over [paged prefix ++ own
    suffix KV] — the oracle for ``kernels/prefill_attention.py``.

    q:          (B, Sq, H, Dh)  — the chunk's queries, sitting at global
                positions ``pos_offset + i`` with
                ``pos_offset = npre * bs`` (prefixes are whole blocks);
    k/v_suffix: (B, Sq, Kh, Dh) — the chunk's own freshly projected KV;
    k/v_pool:   (n_blocks, bs, Kh, Dh) — the shared pool holding the
                prefix blocks (int8 when ``k_scale``/``v_scale``
                (n_blocks, Kh) f32 are given — dequantized here right
                after the gather);
    tables:     (B, npre) int32 — each row's prefix block ids in
                position order (all real tokens: prefixes are full,
                block-aligned).

    The mask/softmax numerics below deliberately REPLICATE
    ``models.attention.sdpa`` (impl="repeat", the serve engine's
    prefill impl) on the concatenated dense view, cast for cast: on fp
    pools the engine's chunked / prefix-hit prefill must produce token
    streams bit-identical to the dense phased path (the serve stream
    contract gated by tests/test_chunked_serve.py and
    scripts/check_ttft_gate.py). Do not "simplify" to the
    flash_attention_ref formulation — it is numerically close but not
    bit-equal.
    """
    b, sq, h, dh = q.shape
    npre = tables.shape[1]
    bs, kh = k_pool.shape[1], k_pool.shape[2]
    pos_offset = npre * bs
    native = k_suffix.dtype
    pk = dequantize_pool(k_pool, tables, k_scale)
    pv = dequantize_pool(v_pool, tables, v_scale)
    k = jnp.concatenate([pk.reshape(b, pos_offset, kh, dh).astype(native),
                         k_suffix], axis=1)
    v = jnp.concatenate([pv.reshape(b, pos_offset, kh, dh).astype(native),
                         v_suffix], axis=1)
    t = pos_offset + sq
    if scale is None:
        sc = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32)).astype(q.dtype)
    else:
        sc = jnp.asarray(scale, jnp.float32).astype(q.dtype)
    qs = q * sc
    if h != kh:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jax.lax.optimization_barrier(
        jnp.einsum("bshk,bthk->bhst", qs, k)).astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None] + pos_offset
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    neg_inf = float(jnp.finfo(jnp.float32).min)
    scores = scores + jnp.where(mask, 0.0, neg_inf).astype(jnp.float32)[
        None, None]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthk->bshk", w, v)


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ssd_chunk_ref(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                  h0: jax.Array):
    """Single-chunk SSD: within-chunk quadratic + carried-in state.

    xdt: (L, H, P); dA: (L, H); B/C: (L, N); h0: (H, P, N).
    Returns (y (L, H, P), h_out (H, P, N)). fp32 math.
    """
    l, nh, p = xdt.shape
    dA_cs = jnp.cumsum(dA.astype(jnp.float32), axis=0)        # (L, H)
    ss = dA_cs[:, None, :] - dA_cs[None, :, :]                # (L, L, H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask[..., None], jnp.exp(ss), 0.0)      # (L, L, H)
    scores = jnp.einsum("ln,sn->ls", C.astype(jnp.float32),
                        B.astype(jnp.float32))
    y_intra = jnp.einsum("ls,lsh,shp->lhp", scores, decay,
                         xdt.astype(jnp.float32))
    y_carry = jnp.einsum("ln,hpn,lh->lhp", C.astype(jnp.float32),
                         h0.astype(jnp.float32), jnp.exp(dA_cs))
    decay_to_end = jnp.exp(dA_cs[-1][None] - dA_cs)           # (L, H)
    h_out = (h0.astype(jnp.float32) * jnp.exp(dA_cs[-1])[:, None, None]
             + jnp.einsum("ln,lh,lhp->hpn", B.astype(jnp.float32),
                          decay_to_end, xdt.astype(jnp.float32)))
    return (y_intra + y_carry).astype(xdt.dtype), h_out.astype(h0.dtype)
