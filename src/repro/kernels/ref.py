"""Pure-jnp oracles for the Pallas kernels. These define the semantics the
kernels must match (asserted over shape/dtype sweeps in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, Dh); k/v: (B, Skv, Kh, Dh), H % Kh == 0 (GQA).

    fp32 softmax, bf16-friendly. Returns (B, Sq, H, Dh) in q.dtype.
    """
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, sq, kh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf)
    if causal:
        qpos = jnp.arange(sq)[:, None] + (k.shape[1] - sq)
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, vf)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, tables: jax.Array,
                               lengths: jax.Array, *,
                               window: Optional[int] = None,
                               scale: Optional[float] = None) -> jax.Array:
    """Single-token GQA decode attention over a paged KV pool.

    q:      (B, H, Dh)        — one query token per sequence;
    k_pool: (n_blocks, bs, Kh, Dh) — the shared KV block pool (v_pool
            alike); block contents cover contiguous position ranges
            [j*bs, (j+1)*bs) of whichever sequence owns the block;
    tables: (B, nb) int32     — per-sequence physical block ids, in
            position order (column j holds positions [j*bs, (j+1)*bs));
            columns a sequence does not own point at the trash block 0;
    lengths:(B,) int32        — true KV length per sequence *including*
            the current token (the query sits at position lengths-1).

    Visible keys are kpos < length (causal: everything at or before the
    query), additionally kpos > length-1-window when windowed. fp32
    softmax; returns (B, H, Dh) in q.dtype. This is the semantics oracle
    the Pallas kernel (kernels/decode_attention.py) must match.
    """
    b, h, dh = q.shape
    nb = tables.shape[1]
    bs, kh = k_pool.shape[1], k_pool.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    k = k_pool[tables].reshape(b, nb * bs, kh, dh).astype(jnp.float32)
    v = v_pool[tables].reshape(b, nb * bs, kh, dh).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, kh, g, dh) * scale
    scores = jnp.einsum("bkgd,btkd->bkgt", qf, k)
    kpos = jnp.arange(nb * bs)[None, :]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos > lengths[:, None] - 1 - window
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v)
    return out.reshape(b, h, dh).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ssd_chunk_ref(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                  h0: jax.Array):
    """Single-chunk SSD: within-chunk quadratic + carried-in state.

    xdt: (L, H, P); dA: (L, H); B/C: (L, N); h0: (H, P, N).
    Returns (y (L, H, P), h_out (H, P, N)). fp32 math.
    """
    l, nh, p = xdt.shape
    dA_cs = jnp.cumsum(dA.astype(jnp.float32), axis=0)        # (L, H)
    ss = dA_cs[:, None, :] - dA_cs[None, :, :]                # (L, L, H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask[..., None], jnp.exp(ss), 0.0)      # (L, L, H)
    scores = jnp.einsum("ln,sn->ls", C.astype(jnp.float32),
                        B.astype(jnp.float32))
    y_intra = jnp.einsum("ls,lsh,shp->lhp", scores, decay,
                         xdt.astype(jnp.float32))
    y_carry = jnp.einsum("ln,hpn,lh->lhp", C.astype(jnp.float32),
                         h0.astype(jnp.float32), jnp.exp(dA_cs))
    decay_to_end = jnp.exp(dA_cs[-1][None] - dA_cs)           # (L, H)
    h_out = (h0.astype(jnp.float32) * jnp.exp(dA_cs[-1])[:, None, None]
             + jnp.einsum("ln,lh,lhp->hpn", B.astype(jnp.float32),
                          decay_to_end, xdt.astype(jnp.float32)))
    return (y_intra + y_carry).astype(xdt.dtype), h_out.astype(h0.dtype)
