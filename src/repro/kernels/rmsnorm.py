"""Pallas TPU fused RMSNorm.

One pass over rows: the (rows, D) input is tiled into (block_rows, D) VMEM
blocks (D up to 8192 bf16 = 16 KB/row — comfortably VMEM-resident); the
mean-square reduction and the scale multiply fuse into a single kernel, so
HBM traffic is exactly read-x + write-y (XLA's unfused chain reads/writes
the fp32 intermediate twice more).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (br, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., D); scale: (D,). Fused rmsnorm over the last dim."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    # pad rows to a block multiple
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
