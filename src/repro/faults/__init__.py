"""Deterministic fault injection + recovery.

``schedule``: seeded, hash-stamped fault schedules (crash, device loss,
straggler slowdown, power-backend failure, checkpoint corruption) that
train/serve/power hooks consult; ``supervisor``: bounded-restart
auto-resume driver around the training loop. Faults are data, not
monkeypatches — identical ``(preset, seed)`` reproduces the identical
failure story, so resilience is benchmarkable like any other workload.
"""
from repro.faults.schedule import (  # noqa: F401
    DeviceLoss,
    FaultEvent,
    FaultSchedule,
    FlakyPower,
    InjectedCrash,
    InjectedFault,
    SERVE_PRESETS,
    TRAIN_PRESETS,
    corrupt_checkpoint,
)
from repro.faults.supervisor import (  # noqa: F401
    SupervisorResult,
    run_supervised,
)
