"""Seeded fault schedules: typed fault events, bit-reproducible by
``(preset, seed)``.

A :class:`FaultSchedule` is built once from a named preset and a seed,
then threaded into the training loop (crash / device loss / slowdown /
checkpoint corruption), the serve engine (slot faults / admission
overload), and the power layer (backend read failures). The schedule is
pure data — event placement is drawn from a ``numpy`` Generator seeded
from ``(seed, sha1(preset))`` — and its canonical-JSON sha1 is stamped
into every benchmark record (``schedule_hash``, mirroring the traffic
subsystem's ``trace_hash``) so a regression report names the exact
failure story it was measured under.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
from dataclasses import asdict, dataclass
from typing import Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Base for scheduled faults. ``transient`` marks them retryable to
    the error classifier in ``core.runner``."""

    transient = True


class InjectedCrash(InjectedFault):
    """Process crash at a training step (1-indexed, post-step)."""

    def __init__(self, step: int):
        super().__init__(f"injected failure at step {step}")
        self.step = step


class DeviceLoss(InjectedFault):
    """Loss of ``n_lost`` devices at a training step — the supervisor
    answers with an elastic rescale, not a plain restart."""

    def __init__(self, step: int, n_lost: int):
        super().__init__(f"injected loss of {n_lost} device(s) "
                         f"at step {step}")
        self.step = step
        self.n_lost = n_lost


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``kind``: crash | device_loss | ckpt_corrupt | slowdown | power_fail
    (train side, ``at`` is a 1-indexed step) or slot_fault | overload
    (serve side, ``at`` indexes decode dispatches / admission polls).
    ``n`` is kind-specific (devices lost, queue cap, failed reads);
    ``seconds`` is the per-step slowdown; ``span`` is how many
    steps/polls the event covers.
    """

    kind: str
    at: int
    n: int = 0
    seconds: float = 0.0
    span: int = 1


#: train-side presets (resilience workload axis values)
TRAIN_PRESETS = ("none", "crash_mid", "crash_double", "ckpt_corrupt",
                 "device_loss", "flaky", "power_fail")
#: serve-side presets
SERVE_PRESETS = ("none", "overload", "decode_fault")

_CRASH_KINDS = ("crash", "device_loss", "ckpt_corrupt")


def _preset_rng(preset: str, seed: int) -> np.random.Generator:
    tag = int.from_bytes(hashlib.sha1(preset.encode()).digest()[:4], "little")
    return np.random.default_rng(np.random.SeedSequence([int(seed), tag]))


class FaultSchedule:
    """An immutable event list plus a small amount of firing state.

    Crash-class events fire at most once per schedule *object*: the
    supervisor shares one schedule across restarts of the same run, so
    a crash scheduled at step 12 kills the first attempt and lets the
    resumed attempt sail past step 12.
    """

    def __init__(self, preset: str, seed: int, total_steps: int,
                 events: tuple):
        self.preset = preset
        self.seed = int(seed)
        self.total_steps = int(total_steps)
        self.events = tuple(events)
        self.fired: set = set()  # indices of one-shot events already fired

    # -- construction -----------------------------------------------------
    @classmethod
    def from_preset(cls, preset: str, seed: int = 0,
                    total_steps: int = 100) -> "FaultSchedule":
        if preset not in TRAIN_PRESETS + SERVE_PRESETS:
            raise ValueError(
                f"unknown fault preset {preset!r}; train presets: "
                f"{TRAIN_PRESETS}, serve presets: {SERVE_PRESETS}")
        rng = _preset_rng(preset, seed)
        mid = max(2, total_steps // 2)
        jit = lambda lo, hi: int(rng.integers(lo, hi + 1))  # noqa: E731
        ev: list[FaultEvent] = []
        if preset == "crash_mid":
            ev.append(FaultEvent("crash", at=mid + jit(-2, 2)))
        elif preset == "crash_double":
            a = max(2, total_steps // 3 + jit(-2, 2))
            b = max(a + 2, 2 * total_steps // 3 + jit(-2, 2))
            ev += [FaultEvent("crash", at=a), FaultEvent("crash", at=b)]
        elif preset == "ckpt_corrupt":
            ev.append(FaultEvent("ckpt_corrupt", at=mid + jit(-2, 2)))
        elif preset == "device_loss":
            ev.append(FaultEvent("device_loss", at=mid + jit(-2, 2),
                                 n=max(1, jit(1, 4))))
        elif preset == "flaky":
            k = 3
            steps = sorted(int(s) for s in rng.choice(
                np.arange(2, max(3, total_steps)), size=k, replace=False))
            ev += [FaultEvent("slowdown", at=s,
                              seconds=round(0.01 + 0.02 * rng.random(), 4))
                   for s in steps]
        elif preset == "power_fail":
            ev.append(FaultEvent("power_fail", at=jit(2, max(3, mid)),
                                 n=jit(2, 5)))
        elif preset == "overload":
            start = jit(3, 8)
            ev.append(FaultEvent("overload", at=start, n=jit(2, 4),
                                 span=jit(4, 8)))
        elif preset == "decode_fault":
            ev.append(FaultEvent("slot_fault", at=jit(4, 12)))
        # "none": empty event list — the fault-free twin shares the
        # schedule machinery (and hash stamping) with the faulted cells.
        return cls(preset, seed, total_steps, tuple(ev))

    # -- identity ---------------------------------------------------------
    def canonical(self) -> dict:
        return {"preset": self.preset, "seed": self.seed,
                "total_steps": self.total_steps,
                "events": [asdict(e) for e in self.events]}

    @property
    def schedule_hash(self) -> str:
        blob = json.dumps(self.canonical(), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    # -- train-side queries (1-indexed steps) -----------------------------
    def crash_at(self, step: int) -> Optional[FaultEvent]:
        """The one-shot crash-class event due at ``step`` (or earlier,
        if a resume skipped past it), if it hasn't fired yet."""
        for i, e in enumerate(self.events):
            if e.kind in _CRASH_KINDS and i not in self.fired and e.at <= step:
                self.fired.add(i)
                return e
        return None

    def slowdown_s(self, step: int) -> float:
        return sum(e.seconds for e in self.events
                   if e.kind == "slowdown" and e.at <= step < e.at + e.span)

    def power_fail_window(self) -> Optional[tuple]:
        """(first failing read index, n failed reads) or None."""
        for e in self.events:
            if e.kind == "power_fail":
                return (e.at, max(1, e.n))
        return None

    # -- serve-side queries (dispatch/poll indices, 0-indexed) ------------
    def queue_cap_at(self, poll: int) -> Optional[int]:
        """Admission-queue cap during an overload window, else None."""
        for e in self.events:
            if e.kind == "overload" and e.at <= poll < e.at + e.span:
                return max(1, e.n)
        return None

    def slot_fault_at(self, decode_idx: int) -> bool:
        """True exactly once per scheduled slot fault."""
        for i, e in enumerate(self.events):
            if (e.kind == "slot_fault" and i not in self.fired
                    and e.at <= decode_idx):
                self.fired.add(i)
                return True
        return False

    def __repr__(self) -> str:
        return (f"FaultSchedule({self.preset!r}, seed={self.seed}, "
                f"hash={self.schedule_hash}, events={len(self.events)})")


def corrupt_checkpoint(ckpt_dir, step: Optional[int] = None) -> Optional[int]:
    """Deterministically corrupt a published checkpoint (newest by
    default): overwrite bytes inside its first leaf file, past the .npy
    header. Returns the corrupted step, or None if there is nothing to
    corrupt. Digest verification in ``ckpt.checkpoint`` detects this
    and falls back to the previous atomic step."""
    from repro.ckpt.checkpoint import latest_step
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    leaves = sorted(d.glob("leaf_*.npy"))
    if not leaves:
        return None
    target = leaves[0]
    size = target.stat().st_size
    off = min(max(0, size - 9), 128)  # past the ~80-byte npy header
    with open(target, "r+b") as f:
        f.seek(off)
        f.write(b"\xff" * min(8, size - off))
    return step


class FlakyPower:
    """Wrap a PowerMethod so a window of ``read()`` calls raises.

    The window is ``(fail_from, fail_count)`` in read-index space — the
    deterministic injection for the power_fail preset. Name/devices are
    delegated so the wrapper is column-compatible with the inner method.
    """

    def __init__(self, inner, fail_from: int, fail_count: int):
        self.inner = inner
        self.name = inner.name
        self.fail_from = int(fail_from)
        self.fail_count = int(fail_count)
        self.reads = 0

    def devices(self):
        return self.inner.devices()

    def available(self) -> bool:
        return self.inner.available()

    def read(self):
        i = self.reads
        self.reads += 1
        if self.fail_from <= i < self.fail_from + self.fail_count:
            raise OSError(f"injected power-backend failure (read {i})")
        return self.inner.read()
