"""Supervised auto-resume: bounded restarts around the training loop.

``run_supervised`` drives a ``run_once(hook)`` callable (build fresh
state, call ``train_loop`` with auto-resume pointed at a shared
``ckpt_dir``) through crashes: each crash costs an exponential-backoff
sleep (with seeded jitter), a resume from the newest *valid* checkpoint
(corrupted steps fall back to the previous atomic one), and — on
:class:`~repro.faults.schedule.DeviceLoss` — an elastic rescale via the
caller's ``on_device_loss`` hook. The supervisor prices what resilience
costs: restarts, wasted (recomputed) steps, backoff seconds, and
``recovery_s`` — wall clock from the crash to the first completed step
of the resumed attempt.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.ckpt.checkpoint import latest_step, latest_valid_step
from repro.faults.schedule import DeviceLoss


@dataclass
class SupervisorResult:
    result: Any                  # the final attempt's LoopResult
    restarts: int = 0
    crash_steps: list = field(default_factory=list)
    resume_steps: list = field(default_factory=list)
    wasted_steps: int = 0        # recomputed steps across all restarts
    recovery_s: float = 0.0      # crash -> first completed resumed step
    backoff_s: float = 0.0       # total injected backoff sleep
    rescales: int = 0            # device-loss rescale responses
    ckpt_fallbacks: int = 0      # resumes that skipped a corrupt newest ckpt


def run_supervised(run_once: Callable[[Callable], Any], *,
                   ckpt_dir,
                   max_restarts: int = 5,
                   backoff_base: float = 0.05,
                   backoff_factor: float = 2.0,
                   backoff_max: float = 2.0,
                   jitter: float = 0.25,
                   seed: int = 0,
                   sleep_fn: Callable[[float], None] = time.sleep,
                   clock: Callable[[], float] = time.monotonic,
                   on_device_loss: Optional[Callable] = None,
                   ) -> SupervisorResult:
    """Run ``run_once(step_hook)`` to completion, restarting on crashes.

    ``run_once`` must accept one argument — a per-step hook with the
    training loop's ``(step, metrics, dt)`` signature — and re-resolve
    its resume point from ``ckpt_dir`` on every call. Crashing more
    than ``max_restarts`` times re-raises the last exception (bounded
    restarts: a deterministic bug must not loop forever). Backoff after
    restart ``k`` (1-indexed) is ``min(backoff_max, backoff_base *
    backoff_factor**(k-1))`` scaled by ``1 + jitter*U[0,1)`` from a
    ``random.Random(seed)`` — injectable ``sleep_fn``/``clock`` keep
    unit tests instant and the schedule reproducible.
    """
    out = SupervisorResult(result=None)
    rng = random.Random(seed)
    crash_t: Optional[float] = None
    step_seen = False

    def hook(step, metrics, dt):
        nonlocal step_seen
        if crash_t is not None and not step_seen:
            out.recovery_s += clock() - crash_t
        step_seen = True

    while True:
        step_seen = False
        try:
            out.result = run_once(hook)
            return out
        except Exception as e:
            out.restarts += 1
            if out.restarts > max_restarts:
                raise
            crash_step = getattr(e, "step", None)
            out.crash_steps.append(crash_step)
            crash_t = clock()
            if isinstance(e, DeviceLoss) and on_device_loss is not None:
                on_device_loss(e)
                out.rescales += 1
            newest = latest_step(ckpt_dir) if ckpt_dir else None
            resume = (latest_valid_step(ckpt_dir) or 0) if ckpt_dir else 0
            out.resume_steps.append(resume)
            if newest is not None and resume != newest:
                out.ckpt_fallbacks += 1
            if crash_step is not None:
                out.wasted_steps += max(0, int(crash_step) - resume)
            k = out.restarts
            delay = min(backoff_max, backoff_base * backoff_factor ** (k - 1))
            delay *= 1.0 + jitter * rng.random()
            sleep_fn(delay)
            out.backoff_s += delay
